"""Kernel-variant and fixpoint-latency sweeps (the BENCH_kernels.json source).

Two measurement surfaces for the device-resident-fixpoint work:

  * `kernels/hindex/*` — the h-index kernel variants at a (N, Cd) grid:
    the O(Cd log Cd) in-tile sort sweep vs the legacy O(Cd*K) count-matrix
    kernel (K = Cd), plus the single-superstep latency of each registry
    backend.  Off-TPU the Pallas rows run in interpret mode — relative
    variant cost, not hardware speed; parity vs `ref.ell_hindex_ref` is
    asserted on every row (this file is part of the --smoke gate).
  * `kernels/coreness/*` — the full min-H fixpoint as ONE fused
    `lax.while_loop` (`ops.coreness_blocks`) vs a host-driven replica of
    the pre-refactor loop (one `device_get` convergence check per
    superstep).  The derived field carries the superstep count so
    us/superstep is recoverable from the JSON trajectory.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import build_blocks, build_ell_random
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert
from repro.kernels import ops, ref

from .common import row, timeit_us


def _timed(fn, reps: int) -> float:
    out = fn()            # warmup / compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / max(1, reps) * 1e6


def _hostloop_coreness(g, backend: str):
    """Pre-refactor fixpoint: one kernel launch + one host sync/superstep."""
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    adj = ops.dense_adj(g, backend)
    steps = 0
    while True:
        h = ops.hindex_blocks(g, est, backend=backend, adj=adj)
        new = jnp.where(g.node_mask, jnp.minimum(est, h), est)
        steps += 1
        if bool(jax.device_get(jnp.all(new == est))):
            break
        est = new
    return est, steps


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    reps = 3 if smoke else 10

    # ---- kernel-variant sweep: sort vs count h-index ------------------
    shapes = [(512, 256)] if smoke else [(512, 256), (2048, 256), (2048, 512)]
    for N, Cd in shapes:
        g = build_ell_random(N, Cd=Cd, seed=seed, m_factor=Cd / 3)
        est = jnp.asarray(g.deg, jnp.int32)
        want = np.asarray(ref.ell_hindex_ref(g.nbr, est))
        K = ops.degree_bound(g)
        us_by = {}
        for variant in ("sort", "count"):
            got = ops.hindex_ell(g.nbr, est, variant=variant)
            np.testing.assert_array_equal(np.asarray(got), want)
            us_by[variant] = _timed(
                lambda v=variant: ops.hindex_ell(g.nbr, est, variant=v), reps)
        for variant, us in us_by.items():
            rows.append(row(
                f"kernels/hindex/N{g.N}/Cd{Cd}/{variant}", us,
                f"K={K};sort_speedup={us_by['count'] / max(us_by['sort'], 1e-9):.1f}x"))
        # degree-bucketed K: same kernel, fewer columns swept
        got = ops.hindex_ell(g.nbr, est, K=K)
        np.testing.assert_array_equal(np.asarray(got), want)
        rows.append(row(
            f"kernels/hindex/N{g.N}/Cd{Cd}/sort_degK",
            _timed(lambda: ops.hindex_ell(g.nbr, est, K=K), reps),
            f"K={K}"))

    # ---- single-superstep latency per backend -------------------------
    n = 240 if smoke else 1000
    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    g = build_blocks(edges, nn, node_random_partition(nn, 8, seed=seed),
                     P=8, deg_slack=24)
    est = jnp.where(g.node_mask, g.deg, 0).astype(jnp.int32)
    want = np.asarray(ref.ell_hindex_ref(g.nbr, est))
    for b in ("jnp", "dense", "ell"):
        got = ops.hindex_blocks(g, est, backend=b)
        np.testing.assert_array_equal(np.asarray(got).astype(want.dtype), want)
        us = _timed(lambda bb=b: ops.hindex_blocks(g, est, backend=bb), reps)
        rows.append(row(f"kernels/superstep/N{g.N}/{b}", us, "parity=ok"))

    # ---- fused vs host-synced fixpoint --------------------------------
    for b in ("jnp", "dense", "ell"):
        core_h, steps_h = _hostloop_coreness(g, b)
        t_host = timeit_us(lambda bb=b: jax.block_until_ready(
            _hostloop_coreness(g, bb)[0]), n=reps)
        def fused(bb=b):
            return ops.coreness_blocks(g, backend=bb, with_steps=True)

        core_f, steps_f = fused()
        np.testing.assert_array_equal(np.asarray(core_h), np.asarray(core_f))
        assert int(steps_f) == steps_h, (b, int(steps_f), steps_h)
        t_fused = _timed(lambda: fused()[0], reps)
        rows.append(row(
            f"kernels/coreness/N{g.N}/{b}/fused", t_fused,
            f"steps={int(steps_f)};"
            f"hostloop_speedup={t_host / max(t_fused, 1e-9):.1f}x"))
        rows.append(row(
            f"kernels/coreness/N{g.N}/{b}/hostloop", t_host,
            f"steps={steps_h}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
