"""Shared benchmark helpers: datasets, timers, CSV rows.

Datasets are the paper's Table 1 entries, generated as shape-matched
stand-ins (SNAP is not redistributable offline; see DESIGN.md §5).  The
default `scale` keeps CI runtime in minutes — pass --full for paper-scale.
"""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import numpy as np

from repro.core import build_blocks
from repro.core.partition import node_random_partition
from repro.graphgen import snap_like

# paper Table 1 datasets at CI scale (nodes kept ~1-4k each)
CI_SCALES: Dict[str, float] = {
    "DS1": 0.04,
    "DS2": 0.02,
    "ego-Facebook": 0.40,
    "roadNet-CA": 0.0012,
    "com-LiveJournal": 0.0005,
}
FULL_SCALES: Dict[str, float] = {k: 1.0 for k in CI_SCALES}
# LiveJournal at 4M nodes exceeds CI memory; paper-scale run caps at 10%.
FULL_SCALES["com-LiveJournal"] = 0.1


def load_dataset(name: str, full: bool = False, seed: int = 0) -> np.ndarray:
    scale = (FULL_SCALES if full else CI_SCALES)[name]
    return snap_like(name, scale=scale, seed=seed)


def build(name: str, P: int = 8, full: bool = False, seed: int = 0):
    edges = load_dataset(name, full=full, seed=seed)
    n = int(edges.max()) + 1
    assign = node_random_partition(n, P, seed=seed)  # paper: random, 8 parts
    g = build_blocks(edges, n, assign, P=P, deg_slack=64)
    return g, edges, n


def timeit_us(fn: Callable, n: int = 1) -> float:
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / max(1, n) * 1e6


def row(name: str, us: float, derived: str = "") -> Tuple[str, float, str]:
    return (name, us, derived)


def print_rows(rows: List[Tuple[str, float, str]]):
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
