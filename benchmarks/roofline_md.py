"""Render the §Roofline table into EXPERIMENTS.md (at the marker)."""
from __future__ import annotations

from pathlib import Path

from .roofline import roofline_rows, model_flops
from repro.configs import ARCHS, SHAPES_BY_NAME

MARK = "<!-- ROOFLINE_TABLE -->"


def render() -> str:
    rows = roofline_rows()
    lines = [
        "| arch | shape | compute s | memory s | collective s | dominant "
        "| MODEL_FLOPS | useful | source |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["status"] == "SKIP":
            lines.append(
                f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | "
                f"{r['reason'][:48]} |")
            continue
        src = ("exact" if (r["unrolled"] and not r.get("extrapolated"))
               else "extrap" if r.get("extrapolated") else "scan*")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['model_flops']:.2e} | "
            f"{r['useful_ratio']:.2f} | {src} |")
    lines.append("")
    lines.append(
        "(source: `exact` = fully-unrolled compile; `extrap` = affine "
        "layer-count extrapolation, flops ±6%, bytes −35% bound, "
        "collectives exact; `scan*` = scan-counted — flop/collective totals "
        "understate by the layer trip count and are superseded wherever an "
        "exact/extrap record exists.  One-sentence lever per dominant term: "
        "compute-bound cells want the §Perf kernel/absorption changes; "
        "memory-bound decode wants ring caches / cache quantization; "
        "collective-bound prefill wants banded attention + "
        "sequence-parallel few-head attention.)")
    return "\n".join(lines)


def main():
    md = Path("EXPERIMENTS.md")
    text = md.read_text()
    assert MARK in text, "marker missing"
    # replace marker (idempotent: keep marker line, replace following block
    # between marker and the next '---'-or-'Reading' sentinel)
    table = render()
    out = text.replace(MARK, MARK + "\n\n" + table, 1) if MARK + "\n\n|" not in text else text
    if MARK + "\n\n|" in text:
        # already rendered: re-render by splitting at marker and next blank
        head, rest = text.split(MARK, 1)
        tail = rest.split("\n\nReading of the table", 1)[1]
        out = head + MARK + "\n\n" + table + "\n\nReading of the table" + tail
    md.write_text(out)
    print(table)


if __name__ == "__main__":
    main()
