"""Paper Tables 3-5: dynamic graph partitioning.

Protocol (paper §5.2.2): partition 90% of the graph (PT = partitioning
time), insert the remaining 10% (UT = update time) under two strategies:

  * IncrementalPart — apply the technique only to the new edges
    (UB-UPDATE for DFEP, per-edge assignment for hash/random)
  * NaivePart       — full repartition from scratch

One table per method: hash (T3), random (T4), DFEP (T5).
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from repro.core.partition_dynamic import (
    initial_partition, incremental_part, naive_part)
from repro.core.partition import edge_balance

from .common import load_dataset, CI_SCALES, row

TABLE_OF = {"hash": "table3", "random": "table4", "dfep": "table5"}


def run(full: bool = False, seed: int = 0, methods=("hash", "random", "dfep"),
        repeats: int = 3) -> List[Tuple[str, float, str]]:
    rows = []
    for method in methods:
        table = TABLE_OF.get(method, f"table_{method}")
        for ds in CI_SCALES:
            edges = load_dataset(ds, full=full, seed=seed)
            rng = np.random.default_rng(seed)
            perm = rng.permutation(len(edges))
            cut = int(0.9 * len(edges))
            base, delta = edges[perm[:cut]], edges[perm[cut:]]
            n = int(edges.max()) + 1

            pts, uts_inc, uts_nv = [], [], []
            for r in range(repeats):
                st0, pt = initial_partition(base, n, 8, method, seed=seed + r)
                st_inc, ut_inc = incremental_part(st0, delta)
                st_nv, ut_nv = naive_part(st0, delta)
                pts.append(pt)
                uts_inc.append(ut_inc)
                uts_nv.append(ut_nv)
                assert len(st_inc.owner) == len(edges)
                assert len(st_nv.owner) == len(edges)
            pt, ut_inc, ut_nv = map(np.mean, (pts, uts_inc, uts_nv))
            bal = edge_balance(st_inc.owner, 8)
            rows.append(row(f"{table}/{ds}/PT/{method}", pt * 1e6,
                            f"s={pt:.3f}"))
            rows.append(row(f"{table}/{ds}/UT/IncrementalPart", ut_inc * 1e6,
                            f"s={ut_inc:.4f};balance={bal:.2f}"))
            rows.append(row(f"{table}/{ds}/UT/NaivePart", ut_nv * 1e6,
                            f"s={ut_nv:.4f};speedup={ut_nv / max(ut_inc, 1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
