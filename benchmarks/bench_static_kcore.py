"""Static distributed k-core decomposition (paper §4.1 step 1): time and
superstep count per dataset — the workerCompute/min-H convergence path that
the Pallas dense-tile kernel accelerates on TPU.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import coreness, coreness_with_stats

from .common import build, CI_SCALES, row


def run(full: bool = False, seed: int = 0) -> List[Tuple[str, float, str]]:
    rows = []
    for ds in CI_SCALES:
        g, edges, n = build(ds, P=8, full=full, seed=seed)
        core = coreness(g)  # compile warmup
        jax.block_until_ready(core)
        t0 = time.perf_counter()
        core = coreness(g)
        jax.block_until_ready(core)
        dt = time.perf_counter() - t0
        _, steps = coreness_with_stats(g)
        maxk = int(np.asarray(core).max())
        rows.append(row(f"kcore_static/{ds}", dt * 1e6,
                        f"s={dt:.3f};supersteps={steps};max_k={maxk};n={n}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
