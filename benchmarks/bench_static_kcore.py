"""Static distributed k-core decomposition (paper §4.1 step 1): time and
superstep count per dataset — the workerCompute/min-H convergence path.

The h-index primitive is obtained through the kernel backend registry;
`backends` sweeps any subset of ("jnp", "dense", "ell").  Off-TPU the Pallas
backends run in interpret mode (parity, not speed — see EXPERIMENTS.md
§Backends); the jnp backend is the CPU performance row.
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax
import numpy as np

from repro.core import coreness, coreness_with_stats

from .common import build, CI_SCALES, row


def run(full: bool = False, seed: int = 0,
        backends: Sequence[str] = ("jnp",)) -> List[Tuple[str, float, str]]:
    rows = []
    for ds in CI_SCALES:
        g, edges, n = build(ds, P=8, full=full, seed=seed)
        _, steps = coreness_with_stats(g)
        for b in backends:
            core = coreness(g, backend=b)  # compile warmup
            jax.block_until_ready(core)
            t0 = time.perf_counter()
            core = coreness(g, backend=b)
            jax.block_until_ready(core)
            dt = time.perf_counter() - t0
            maxk = int(np.asarray(core).max())
            rows.append(row(f"kcore_static/{ds}/{b}", dt * 1e6,
                            f"s={dt:.3f};supersteps={steps};max_k={maxk};n={n}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
