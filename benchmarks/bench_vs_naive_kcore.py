"""Paper Figure 7: BLADYG incremental k-core maintenance vs the baseline.

The paper's baseline (Aksu et al., HBase) maintains a SINGLE fixed-k core
per pass — achieving the full decomposition costs max(k) passes.  Our
implemented baseline is the stronger one: full min-H recomputation from
scratch on every update (one pass, all k).  We report both:

  * incremental  — Theorem-1 candidate search + restricted recompute
  * naive        — full coreness() recompute after each update
  * speedup      — naive / incremental (derived column)
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coreness, insert_edge_maintain, insert_edge
from repro.core.updates import sample_insertions

from .common import build, CI_SCALES, row


def run(updates: int = 10, full: bool = False, seed: int = 0
        ) -> List[Tuple[str, float, str]]:
    rows = []
    for ds in CI_SCALES:
        g0, edges, n = build(ds, P=8, full=full, seed=seed)
        core0 = coreness(g0)
        jax.block_until_ready(core0)
        ups = sample_insertions(g0, updates + 1, "inter", seed=seed)

        # incremental
        g = jax.tree.map(lambda x: x.copy(), g0)
        core = core0.copy()
        u, v, _ = ups[0]
        g, core, _ = insert_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
        jax.block_until_ready(core)
        t0 = time.perf_counter()
        for u, v, _ in ups[1:]:
            g, core, _ = insert_edge_maintain(g, core, jnp.int32(u), jnp.int32(v))
        jax.block_until_ready(core)
        inc_ms = (time.perf_counter() - t0) / updates * 1e3
        core_inc = np.asarray(core)

        # naive full recompute
        g = jax.tree.map(lambda x: x.copy(), g0)
        u, v, _ = ups[0]
        g = insert_edge(g, jnp.int32(u), jnp.int32(v))
        core = coreness(g)
        jax.block_until_ready(core)
        t0 = time.perf_counter()
        for u, v, _ in ups[1:]:
            g = insert_edge(g, jnp.int32(u), jnp.int32(v))
            core = coreness(g)
        jax.block_until_ready(core)
        naive_ms = (time.perf_counter() - t0) / updates * 1e3
        core_naive = np.asarray(core)

        assert (core_inc == core_naive).all(), f"{ds}: mismatch vs naive"
        speedup = naive_ms / max(inc_ms, 1e-9)
        rows.append(row(f"fig7/{ds}/incremental", inc_ms * 1e3,
                        f"ms={inc_ms:.2f}"))
        rows.append(row(f"fig7/{ds}/naive", naive_ms * 1e3,
                        f"ms={naive_ms:.2f};speedup={speedup:.1f}x"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
