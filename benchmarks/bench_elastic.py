"""Elasticity costs: snapshot save/restore, capacity growth, recovery.

What the elastic machinery costs at each scale, so regressions in the
host-side relocation/serialization paths show up in the BENCH trajectory:

  * `elastic/save/N<n>` — blocking `save_session` (device->host pull of
    every array + atomic rename); derived carries the on-disk byte size.
  * `elastic/restore/N<n>` — `restore_session` from the committed step
    (manifest-driven, no `like` template).
  * `elastic/grow_cd/N<n>`, `elastic/grow_cn/N<n>` — one live-session
    capacity escalation (pad-and-rekey relocation + analytics ride-along
    + remap compose).  Growth doubles the respective capacity, so this
    is the worst-case single step of the pow2 escalation ladder.
  * `elastic/recover/N<n>` — the full worker-loss drill: restore from
    the snapshot, evacuate the dead block across the survivors
    (`migrate_vertices` permutation), replay a 2-window log tail.

All rows run on the jnp backend (host relocation dominates; the
device-side executor re-key is covered by bench_stream's spmd rows) and
time the SECOND call of everything jitted, so compile time stays out of
the trajectory.
"""
from __future__ import annotations

import shutil
import tempfile
import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import build_blocks, connected_components, coreness
from repro.core.partition import node_random_partition
from repro.graphgen import barabasi_albert
from repro.runtime.recovery import ElasticCoordinator
from repro.runtime.stream import StreamSession
from repro.checkpoint import CheckpointManager, restore_session, save_session

from .common import row


def _session(n: int, seed: int, P: int = 8) -> StreamSession:
    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    assign = node_random_partition(nn, P, seed=seed + 1)
    g = build_blocks(edges, nn, assign, P=P, deg_slack=8, node_slack=8)
    return StreamSession(g, coreness(g, backend="jnp"), R=8,
                         cc_labels=connected_components(g), auto_grow=True)


def _windows(sess: StreamSession, k: int, seed: int):
    g = sess.g
    rng = np.random.default_rng(seed)
    real = np.flatnonzero(np.asarray(g.node_mask))
    nbr = np.asarray(g.nbr)
    cur = set()
    for i in real:
        for j in nbr[i]:
            if j >= 0:
                cur.add((min(int(i), int(j)), max(int(i), int(j))))
    out = []
    for _ in range(k):
        w = []
        while len(w) < 6:
            u, v = (int(real[rng.integers(0, len(real))]) for _ in range(2))
            key = (min(u, v), max(u, v))
            if u != v and key not in cur:
                cur.add(key)
                w.append((u, v, +1))
        out.append(w)
    return out


def _time_ms(fn, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best * 1e3


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    sizes = (300,) if smoke else (300, 1200, 4800)
    for n in sizes:
        tmp = tempfile.mkdtemp()
        try:
            mgr = CheckpointManager(tmp, keep_n=2)
            sess = _session(n, seed)
            for w in _windows(sess, 2, seed + 2):
                sess.apply_window(w)  # realistic mid-stream state

            us_save = 1e3 * _time_ms(
                lambda: save_session(mgr, sess, step=1))
            step_dir = mgr.dir / "step_00000001"
            nbytes = sum(p.stat().st_size for p in step_dir.iterdir())
            rows.append(row(f"elastic/save/N{n}", us_save,
                            f"bytes={nbytes};P={sess.g.P};Cn={sess.g.Cn};"
                            f"Cd={sess.g.Cd}"))

            us_restore = 1e3 * _time_ms(
                lambda: restore_session(mgr, step=1))
            rows.append(row(f"elastic/restore/N{n}", us_restore,
                            f"bytes={nbytes}"))

            # growth: each measurement needs a fresh session (grow
            # mutates); time the pow2 doubling step
            def grow_cd():
                s = _session(n, seed)
                t0 = time.perf_counter()
                s.grow(Cd=s.g.Cd * 2)
                return time.perf_counter() - t0

            def grow_cn():
                s = _session(n, seed)
                t0 = time.perf_counter()
                s.grow(Cn=s.g.Cn * 2)
                return time.perf_counter() - t0

            for name, fn in (("grow_cd", grow_cd), ("grow_cn", grow_cn)):
                best = min(fn() for _ in range(3))
                rows.append(row(f"elastic/{name}/N{n}", best * 1e6,
                                f"N={sess.g.N}"))

            # the worker-loss drill end to end (restore + evacuate +
            # 2-window replay); coordinator rebuilt per repeat
            def drill():
                coord = ElasticCoordinator(_session(n, seed), mgr2)
                for w in ws_drill:
                    coord.apply_window(w)
                coord.checkpoint()
                tail = _windows(coord.session, 2, seed + 7)
                for w in tail:
                    coord.apply_window(w)
                t0 = time.perf_counter()
                coord.recover_worker(0)
                return time.perf_counter() - t0

            tmp2 = tempfile.mkdtemp()
            try:
                mgr2 = CheckpointManager(tmp2, keep_n=2)
                ws_drill = _windows(_session(n, seed), 2, seed + 5)
                best = min(drill() for _ in range(2))
                rows.append(row(f"elastic/recover/N{n}", best * 1e6,
                                "dead_blocks=1;replay_windows=2"))
            finally:
                shutil.rmtree(tmp2, ignore_errors=True)
        finally:
            shutil.rmtree(tmp, ignore_errors=True)
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
