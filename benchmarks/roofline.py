"""Roofline aggregation: reads the dry-run JSONs (experiments/roofline for
the exact unrolled pass, experiments/dryrun for the compile-proof pass) and
emits the three-term roofline table per (arch × shape) — EXPERIMENTS.md
§Roofline is generated from this.

Terms (TPU v5e, per chip: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link):

  compute    = HLO_FLOPs / (chips · peak)      [per-device flops / peak]
  memory     = HLO_bytes / (chips · HBM_bw)
  collective = collective_bytes / (chips · link_bw)

MODEL_FLOPS = 6·N·D (dense) or 6·N_active·D (MoE) per train step; decode /
prefill use 2·N(_active)·D per generated/processed token.  The ratio
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy overhead.
"""
from __future__ import annotations

import glob
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

from repro.configs import ARCHS, SHAPES_BY_NAME

PEAK = 197e12
HBM = 819e9
LINK = 50e9


def active_params(cfg) -> Tuple[float, float]:
    """(total params, active params per token), analytic."""
    d = cfg.d_model
    V = cfg.vocab
    emb = V * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_impl == "mla":
            q = d * cfg.q_lora_rank + cfg.q_lora_rank * cfg.n_heads * (
                cfg.qk_nope_head_dim + cfg.qk_rope_head_dim)
            kv = d * (cfg.kv_lora_rank + cfg.qk_rope_head_dim) + \
                cfg.kv_lora_rank * cfg.n_heads * (cfg.qk_nope_head_dim
                                                  + cfg.v_head_dim)
            o = cfg.n_heads * cfg.v_head_dim * d
            return q + kv + o
        hd = cfg.hd
        return d * hd * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * hd * d

    def mlp_params(ff):
        return 3 * d * ff

    def mamba_params():
        d_in = cfg.d_inner
        G, N = cfg.ssm_groups, cfg.ssm_state
        return d * (2 * d_in + 2 * G * N + cfg.n_ssm_heads) + d_in * d

    total = emb
    act = emb
    if cfg.mixer == "mamba":
        total += cfg.n_layers * mamba_params()
        act += cfg.n_layers * mamba_params()
        if cfg.shared_attn_period:
            shared = attn_params() + mlp_params(cfg.d_ff)
            total += shared
            act += shared * (cfg.n_layers // cfg.shared_attn_period)
    elif cfg.n_experts:
        dense_layers = cfg.first_k_dense
        moe_layers = cfg.n_layers - dense_layers
        total += cfg.n_layers * attn_params()
        act += cfg.n_layers * attn_params()
        total += dense_layers * mlp_params(cfg.dense_d_ff or cfg.d_ff)
        act += dense_layers * mlp_params(cfg.dense_d_ff or cfg.d_ff)
        expert = mlp_params(cfg.moe_d_ff)
        total += moe_layers * cfg.n_experts * expert
        act += moe_layers * cfg.top_k * expert
        if cfg.n_shared_experts:
            total += moe_layers * cfg.n_shared_experts * mlp_params(cfg.moe_d_ff)
            act += moe_layers * cfg.n_shared_experts * mlp_params(cfg.moe_d_ff)
    else:
        per = attn_params() + mlp_params(cfg.d_ff)
        layers = cfg.n_layers + cfg.enc_layers
        if cfg.is_encdec:
            per_dec = attn_params() * 2 + mlp_params(cfg.d_ff)
            total += cfg.enc_layers * per + cfg.n_layers * per_dec
            act = total
        else:
            total += layers * per
            act += layers * per
    return float(total), float(act)


def model_flops(cfg, shape) -> float:
    """Analytic MODEL_FLOPS for the whole step (global, all chips)."""
    total, act = active_params(cfg)
    tokens = shape.global_batch * shape.seq_len
    if shape.kind == "train":
        return 6.0 * act * tokens
    if shape.kind == "prefill":
        return 2.0 * act * tokens
    # decode: one token per sequence
    return 2.0 * act * shape.global_batch


def load_records(dirs=("experiments/roofline", "experiments/perf",
                       "experiments/perf2", "experiments/dryrun")
                 ) -> List[Dict]:
    recs = []
    for d in dirs:
        for f in glob.glob(str(Path(d) / "*.json")):
            recs.append(json.load(open(f)))
    return recs


def best_record(recs, arch, shape, mesh="16x16") -> Optional[Dict]:
    """Prefer unrolled (exact) over scanned records."""
    cands = [r for r in recs
             if r["arch"] == arch and r["shape"] == shape
             and r["mesh"] == mesh and r["status"] == "OK"
             and not r.get("mla_absorbed") and not r.get("ring")]
    if not cands:
        return None
    # preference: fully-unrolled exact > affine-extrapolated > scanned
    cands.sort(key=lambda r: (not r.get("unrolled", False),
                              bool(r.get("extrapolated", False))))
    return cands[0]


def roofline_rows(mesh="16x16"):
    recs = load_records()
    rows = []
    for arch, cfg in ARCHS.items():
        for sname, shape in SHAPES_BY_NAME.items():
            r = best_record(recs, arch, sname, mesh)
            if r is None:
                skips = [x for x in recs if x["arch"] == arch
                         and x["shape"] == sname and x["status"] == "SKIP"]
                if skips:
                    rows.append({"arch": arch, "shape": sname,
                                 "status": "SKIP",
                                 "reason": skips[0].get("reason", "")[:60]})
                continue
            chips = r["chips"]
            ct = r["compute_term_s"]
            mt = r["memory_term_s"]
            lt = r["collective_term_s"]
            dom = max((ct, "compute"), (mt, "memory"), (lt, "collective"))[1]
            mf = model_flops(cfg, SHAPES_BY_NAME[sname])
            hlo_total = r["per_device_flops"] * chips
            ratio = mf / hlo_total if hlo_total else 0.0
            bound = max(ct, mt, lt)
            frac = ct / bound if bound else 0.0  # roofline fraction: compute share
            rows.append({
                "arch": arch, "shape": sname, "status": "OK",
                "unrolled": r.get("unrolled", False),
                "extrapolated": bool(r.get("extrapolated", False)),
                "compute_s": ct, "memory_s": mt, "collective_s": lt,
                "dominant": dom, "model_flops": mf,
                "hlo_flops_total": hlo_total, "useful_ratio": ratio,
                "roofline_fraction": frac,
            })
    return rows


def run(full=False, seed=0):
    """CSV rows for benchmarks.run."""
    out = []
    for r in roofline_rows():
        if r["status"] != "OK":
            out.append((f"roofline/{r['arch']}/{r['shape']}", 0.0,
                        f"SKIP:{r.get('reason','')[:40]}"))
            continue
        dom_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        out.append((
            f"roofline/{r['arch']}/{r['shape']}",
            dom_s * 1e6,
            (f"dom={r['dominant']};C={r['compute_s']:.2e};"
             f"M={r['memory_s']:.2e};L={r['collective_s']:.2e};"
             f"useful={r['useful_ratio']:.2f};"
             f"exact={'extrap' if r.get('extrapolated') else 'y' if r['unrolled'] else 'scan'}"),
        ))
    return out


def main():
    rows = roofline_rows()
    hdr = (f"{'arch':26s} {'shape':12s} {'compute_s':>10s} {'memory_s':>10s} "
           f"{'collect_s':>10s} {'dominant':>10s} {'useful':>7s} {'exact':>6s}")
    print(hdr)
    for r in rows:
        if r["status"] == "SKIP":
            print(f"{r['arch']:26s} {r['shape']:12s} {'SKIP: '+r['reason']}")
            continue
        print(f"{r['arch']:26s} {r['shape']:12s} {r['compute_s']:10.2e} "
              f"{r['memory_s']:10.2e} {r['collective_s']:10.2e} "
              f"{r['dominant']:>10s} {r['useful_ratio']:7.2f} "
              f"{'unroll' if r['unrolled'] else 'scan':>6s}")


if __name__ == "__main__":
    main()
