"""Per-kernel roofline points (the ``benchmarks.run --profile`` payload).

For each ELL kernel of the registry this module pairs an analytic
operation model — FLOPs (integer compares count as ops) and HBM bytes
moved per dispatch, derived from the kernel's loop structure — with a
measured wall-clock time, and emits one roofline point per kernel:

  intensity          = flops / bytes            [ops per byte]
  roofline_bound_us  = max(flops/peak_flops, bytes/peak_bw)
  achieved_fraction  = roofline_bound_us / measured_us   (1.0 = on the
                       roofline; off-TPU interpret-mode fractions are
                       tiny and only the RELATIVE ordering is meaningful)

The points land in ``PROFILE_kernels.json`` next to the BENCH_*.json
trajectory files (the distinct prefix keeps ``check_regression``'s
``BENCH_*`` glob away from them — profile points carry platform peaks,
not comparable row timings) and ride the same CI artifact upload.

Peaks: TPU v5e per chip (197 TFLOP/s bf16, 819 GB/s HBM) when on TPU;
a nominal 50 GFLOP/s / 25 GB/s single-stream envelope on CPU hosts,
where the numbers locate kernels on the roofline qualitatively.
"""
from __future__ import annotations

import math
import time
from typing import Dict, List

import jax
import jax.numpy as jnp

from repro.core import build_ell_random
from repro.kernels import ops

#: (peak_flops/s, peak_bytes/s) per jax platform
PEAKS = {
    "tpu": (197e12, 819e9),
    "cpu": (50e9, 25e9),
}


def _timed_us(fn, reps: int = 3) -> float:
    out = fn()
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / max(1, reps) * 1e6


def _pad128(x: int) -> int:
    return -(-x // 128) * 128


def kernel_models(N: int, Cd: int) -> List[Dict]:
    """Analytic (flops, bytes) per dispatch for each profiled kernel.

    C is the padded column count the kernels actually sweep; int32
    everywhere (4 bytes).  Compares/selects count as 1 op.
    """
    C = _pad128(Cd)
    Np = _pad128(N)
    lg = max(1, math.ceil(math.log2(C)))
    nbr_bytes = Np * C * 4             # one adjacency sweep
    vec_bytes = Np * 4                 # one (N,) field or output
    gather_bytes = Np * C * 4          # one (N, C) gathered value matrix
    return [
        dict(name=f"hindex_sort/N{N}/Cd{Cd}",
             flops=Np * C * (lg + 1),          # bitonic compares + rank test
             bytes=nbr_bytes + gather_bytes + vec_bytes * 2),
        dict(name=f"cc_min/N{N}/Cd{Cd}",
             flops=Np * C,                     # row min
             bytes=nbr_bytes + gather_bytes + vec_bytes * 2),
        dict(name=f"pagerank_sum/N{N}/Cd{Cd}",
             flops=Np * C,                     # row sum
             bytes=nbr_bytes + gather_bytes + vec_bytes * 2),
        dict(name=f"multi_fused/N{N}/Cd{Cd}",
             flops=Np * C * (lg + 3),          # shared mask + 3 reduces
             bytes=nbr_bytes + 3 * (gather_bytes + vec_bytes * 2)),
        dict(name=f"triangles_merge/N{N}/Cd{Cd}",
             flops=Np * C * C * 2 * lg,        # dual bisect per (slot, elem)
             bytes=nbr_bytes * 2 + Np * C * C * 4),  # per-slot row gathers
        dict(name=f"triangles_allpairs/N{N}/Cd{Cd}",
             flops=Np * C * C * C,             # all-pairs id compares
             bytes=nbr_bytes * 2 + Np * C * C * 4),
    ]


def profile_points(seed: int = 0, N: int = 320, Cd: int = 24,
                   reps: int = 3) -> Dict:
    """Measure every modeled kernel once and attach roofline terms."""
    platform = jax.devices()[0].platform
    peak_f, peak_b = PEAKS.get(platform, PEAKS["cpu"])
    g = build_ell_random(N, Cd=Cd, seed=seed, m_factor=Cd / 3)
    est = jnp.asarray(g.deg, jnp.int32)
    lab = jnp.arange(g.N, dtype=jnp.int32)
    contrib = jnp.where(g.deg > 0, 1.0 / jnp.maximum(g.deg, 1),
                        0.0).astype(jnp.float32)
    dispatch = {
        "hindex_sort": lambda: ops.hindex_ell(g.nbr, est),
        "cc_min": lambda: ops.neighbor_min_ell(g.nbr, lab),
        "pagerank_sum": lambda: ops.neighbor_sum_ell(g.nbr, contrib),
        "multi_fused": lambda: ops.neighbor_multi_ell(
            g.nbr, (est, lab, contrib), ("hindex", "min", "sum")),
        "triangles_merge": lambda: ops.neighbor_common_ell(
            g.nbr, g.nbr, variant="merge"),
        "triangles_allpairs": lambda: ops.neighbor_common_ell(
            g.nbr, g.nbr, variant="allpairs"),
    }
    points = []
    for model in kernel_models(g.N, g.Cd):
        key = model["name"].split("/")[0]
        us = _timed_us(dispatch[key], reps)
        bound_us = max(model["flops"] / peak_f,
                       model["bytes"] / peak_b) * 1e6
        points.append({
            **model,
            "us_per_call": round(us, 1),
            "intensity_flops_per_byte": round(
                model["flops"] / model["bytes"], 3),
            "roofline_bound_us": round(bound_us, 3),
            "achieved_fraction": round(bound_us / max(us, 1e-9), 6),
        })
    return {
        "profile": "kernels",
        "platform": {
            "jax_backend": platform,
            "device_count": len(jax.devices()),
        },
        "peaks": {"flops_per_s": peak_f, "bytes_per_s": peak_b},
        "points": points,
    }


if __name__ == "__main__":
    import json

    print(json.dumps(profile_points(), indent=2))
