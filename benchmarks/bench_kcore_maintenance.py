"""Paper Table 2: average insertion time (AIT) / average deletion time (ADT)
for inter- vs intra-partition edge updates, per dataset, 8 blocks.

The measured quantity is the full BLADYG maintenance latency per update:
candidate search (Theorem 1 frontier) + restricted coreness recompute +
graph mutation, end to end, after JIT warmup — the same protocol as the
paper (averaged over the update batch).
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coreness, insert_edge_maintain, delete_edge_maintain
from repro.core.updates import sample_insertions, sample_deletions

from .common import build, CI_SCALES, row


def _run_updates(g, core, ups, fn):
    # warmup/compile on the first update, then time the rest
    (u, v, _) = ups[0]
    g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v))
    jax.block_until_ready(core)
    times = []
    for u, v, _ in ups[1:]:
        t0 = time.perf_counter()
        g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v))
        jax.block_until_ready(core)
        times.append(time.perf_counter() - t0)
    return g, core, float(np.mean(times)) * 1e3  # ms


def run(updates: int = 30, full: bool = False, seed: int = 0
        ) -> List[Tuple[str, float, str]]:
    rows = []
    for ds in CI_SCALES:
        g0, edges, n = build(ds, P=8, full=full, seed=seed)
        core0 = coreness(g0)
        jax.block_until_ready(core0)
        for scenario in ("inter", "intra"):
            # insertions
            g = jax.tree.map(lambda x: x.copy(), g0)
            core = core0.copy()
            ins = sample_insertions(g, updates, scenario, seed=seed + 1)
            g, core, ait = _run_updates(g, core, ins, insert_edge_maintain)
            rows.append(row(f"table2/{ds}/AIT/{scenario}", ait * 1e3,
                            f"ms={ait:.2f};n={n}"))
            # deletions (delete the edges we just inserted ∪ existing)
            dels = sample_deletions(g, updates, scenario, seed=seed + 2)
            g, core, adt = _run_updates(g, core, dels, delete_edge_maintain)
            rows.append(row(f"table2/{ds}/ADT/{scenario}", adt * 1e3,
                            f"ms={adt:.2f};n={n}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
