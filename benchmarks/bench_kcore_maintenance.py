"""Paper Table 2: average insertion time (AIT) / average deletion time (ADT)
for inter- vs intra-partition edge updates, per dataset, 8 blocks.

The measured quantity is the full BLADYG maintenance latency per update:
candidate search (Theorem 1 frontier) + restricted coreness recompute +
graph mutation, end to end, after JIT warmup — the same protocol as the
paper (averaged over the update batch).

`batch_sizes` additionally sweeps `maintain_batch`: R updates share one
batched k-reachability search on the frontier kernels' R axis (conflicting
candidate sets fall back to sequential, so the amortization seen here is
data-dependent — see EXPERIMENTS.md §Batched maintenance).
"""
from __future__ import annotations

import time
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    coreness, insert_edge_maintain, delete_edge_maintain, maintain_batch,
)
from repro.core.updates import sample_insertions, sample_deletions

from .common import build, CI_SCALES, row


def _run_updates(g, core, ups, fn):
    # warmup/compile on the first update, then time the rest
    (u, v, _) = ups[0]
    g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v))
    jax.block_until_ready(core)
    times = []
    for u, v, _ in ups[1:]:
        t0 = time.perf_counter()
        g, core, st = fn(g, core, jnp.int32(u), jnp.int32(v))
        jax.block_until_ready(core)
        times.append(time.perf_counter() - t0)
    return g, core, float(np.mean(times)) * 1e3  # ms


def run(updates: int = 30, full: bool = False, seed: int = 0,
        batch_sizes: Sequence[int] = ()) -> List[Tuple[str, float, str]]:
    rows = []
    for ds in CI_SCALES:
        g0, edges, n = build(ds, P=8, full=full, seed=seed)
        core0 = coreness(g0)
        jax.block_until_ready(core0)
        for scenario in ("inter", "intra"):
            # insertions
            g = jax.tree.map(lambda x: x.copy(), g0)
            core = core0.copy()
            ins = sample_insertions(g, updates, scenario, seed=seed + 1)
            g, core, ait = _run_updates(g, core, ins, insert_edge_maintain)
            rows.append(row(f"table2/{ds}/AIT/{scenario}", ait * 1e3,
                            f"ms={ait:.2f};n={n}"))
            # deletions (delete the edges we just inserted ∪ existing)
            dels = sample_deletions(g, updates, scenario, seed=seed + 2)
            g, core, adt = _run_updates(g, core, dels, delete_edge_maintain)
            rows.append(row(f"table2/{ds}/ADT/{scenario}", adt * 1e3,
                            f"ms={adt:.2f};n={n}"))
        # batched maintenance: same insertion stream, amortized supersteps
        for R in batch_sizes:
            g = jax.tree.map(lambda x: x.copy(), g0)
            core = core0.copy()
            # warm the *batched* path (a >=2-update chunk compiles
            # _batch_candidates/_apply_and_recompute; a 1-update chunk
            # would only warm the sequential shortcut); sample warm extra
            # updates so the timed stream always has `updates` entries
            warm = max(2, R)
            ins = sample_insertions(g, updates + warm, "inter", seed=seed + 3)
            g, core, _ = maintain_batch(g, core, ins[:warm], R=R)
            t0 = time.perf_counter()
            g, core, bst = maintain_batch(g, core, ins[warm:], R=R)
            jax.block_until_ready(core)
            dt = time.perf_counter() - t0
            per_ms = dt / (len(ins) - warm) * 1e3
            rows.append(row(
                f"table2/{ds}/batched/R{R}", per_ms * 1e3,
                f"ms={per_ms:.2f};bfs_steps={bst.bfs_steps};"
                f"rec_steps={bst.recompute_steps};"
                f"batched={bst.batched_updates}/{bst.updates};n={n}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
