"""Distributed runtime: mesh coreness parity/time + W2W accounting.

Two measurement surfaces for the block runtime (`repro.runtime`):

  * `runtime/coreness/*` — full min-H coreness through the single-device
    jnp path vs the shard_map mesh path (`ell_spmd`), with the bit-parity
    asserted.  On a 1-device host the mesh path still executes (W = 1,
    all blocks folded) — the interesting numbers come from the
    multi-device CI job / real hardware.
  * `runtime/w2w/*` — the paper's inter- vs intra-partition message
    accounting, twice: metered (the engine's declared
    `halo_slot_counts` payload) and executed (the runtime `HaloPlan`'s
    slot counts + deduplicated device payload).  The slot-level numbers
    must agree exactly; the device payload shows what deduplication
    saves on the wire.
  * `runtime/overlap/*` — the split-phase halo read
    (`SpmdExecutor(overlap=True)`, local slots gather without waiting on
    the all_to_all) vs strict ordering, same mesh coreness fixpoint:
    bit-parity asserted, serialized-collective-phase counts in the
    derived field (0/superstep overlap, 1/superstep strict).  On a
    1-device host both paths time the same local math — the spread is a
    multi-device / real-hardware number.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import jax
import numpy as np

from repro.core import build_blocks, coreness, coreness_via_engine, \
    coreness_via_spmd
from repro.core.partition import node_bfs_partition
from repro.graphgen import barabasi_albert

from .common import row


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    n = 300 if smoke else 1500
    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    assign = node_bfs_partition(edges, nn, 4, seed=seed)
    g = build_blocks(edges, nn, assign, P=4, deg_slack=48)

    rows = []
    times = {}
    cores = {}
    for backend in ("jnp", "ell_spmd"):
        core = coreness(g, backend=backend)  # warmup/compile
        jax.block_until_ready(core)
        t0 = time.perf_counter()
        core = coreness(g, backend=backend)
        jax.block_until_ready(core)
        times[backend] = time.perf_counter() - t0
        cores[backend] = np.asarray(core)
    assert (cores["jnp"] == cores["ell_spmd"]).all(), "mesh parity broken"
    W = len(jax.devices())
    for backend, t in times.items():
        rows.append(row(f"runtime/coreness/{backend}", t * 1e6,
                        f"n={nn};P=4;devices={W}"))

    # ---- overlap vs strict halo ordering, same fixpoint ---------------
    from repro.runtime.spmd import SpmdExecutor

    ov_core = {}
    for ov in (True, False):
        ex = SpmdExecutor(g, overlap=ov)
        est, steps = ex.coreness()  # warmup/compile
        jax.block_until_ready(est)
        t0 = time.perf_counter()
        est, steps = ex.coreness()
        jax.block_until_ready(est)
        dt = time.perf_counter() - t0
        ov_core[ov] = np.asarray(est)
        mode = "overlap" if ov else "strict"
        ser = (0 if ov else 1) * int(steps)
        rows.append(row(f"runtime/overlap/coreness/{mode}", dt * 1e6,
                        f"serialized_collectives={ser};"
                        f"steps={int(steps)};devices={W}"))
    assert (ov_core[True] == cores["jnp"]).all(), "overlap parity broken"
    assert (ov_core[False] == cores["jnp"]).all(), "strict parity broken"

    _, eng_m = coreness_via_engine(g)
    _, eng_x = coreness_via_spmd(g)
    tm, tx = eng_m.message_totals(), eng_x.message_totals()
    assert (tm.w2w_intra, tm.w2w_inter) == (tx.w2w_intra, tx.w2w_inter), \
        "executed halo counts diverge from metering"
    plan = eng_x.ex.plan
    rows.append(row("runtime/w2w/metered", 0.0,
                    f"intra={tm.w2w_intra};inter={tm.w2w_inter};"
                    f"steps={len(eng_m.traces)}"))
    rows.append(row("runtime/w2w/executed", 0.0,
                    f"intra={tx.w2w_intra};inter={tx.w2w_inter};"
                    f"device_elems_per_step={plan.device_elems};"
                    f"W={plan.wm.W}"))
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
