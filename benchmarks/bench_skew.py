"""Hub-mirroring skew sweep (the BENCH_skew.json source).

BA stand-ins (`snap_like("ego-Facebook")`, the paper's social-graph
shape) at 2-3 skew levels — max/mean degree grows with scale because a
BA hub's degree grows as sqrt(n) while the mean stays ~2k.  Per level:

  * `skew/<lvl>/alloc` — counter row (no timing): the ELL allocation
    `N*Cd` unsplit vs split, the inter/intra halo slot counts, and the
    per-superstep mirror-merge payload, straight from
    `hub_split.mirror_report`.  The acceptance gates ride here and are
    ASSERTED (like bench_runtime's parity gates): at every level where
    max degree >= 8x mean, splitting must cut the allocation >= 4x and
    shrink the inter-block halo slots.
  * `skew/<lvl>/coreness_{unsplit,split}` — the full min-H fixpoint on
    the same logical graph through both layouts (jnp backend; the split
    run goes through the mirror merge), bit-parity asserted at
    primaries.  This is the direct read on what bounding Cd by the
    split threshold buys the kernel pass on skewed graphs.
  * `skew/<lvl>/window_{plain,mirror}` — host cost of applying one
    8-edit window: `apply_updates_host` on the unsplit layout vs
    `hub_split.apply_mirrored_edits` (slice routing + plan rebuild) on
    the split one.

`kernel_rows`/`stream_rows` expose the timing surfaces to
`bench_kernels`/`bench_stream` so the skew trajectory also rides the
files the hard/soft regression tiers already watch.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np
import jax

from repro.core import build_blocks
from repro.core.hub_split import apply_mirrored_edits, mirror_report, \
    split_hubs
from repro.core.kcore import coreness
from repro.core.partition import node_random_partition
from repro.core.updates import apply_updates_host
from repro.graphgen import snap_like

from .common import timeit_us

#: (level name, ego-Facebook scale, split threshold)
LEVELS = (("lo", 0.05, 64), ("mid", 0.15, 64), ("hi", 0.4, 64))


def _build_level(scale: float, threshold: int, seed: int, P: int = 8):
    edges = snap_like("ego-Facebook", scale=scale, seed=seed)
    n = int(edges.max()) + 1
    deg = np.bincount(edges.ravel(), minlength=n)
    # enough padding rows for every replica the split will allocate
    replicas = int(np.maximum(0, -(-deg // threshold) - 1).sum())
    assign = node_random_partition(n, P, seed=seed)
    g = build_blocks(edges, n, assign, P=P, node_slack=replicas)
    g2, plan = split_hubs(g, threshold=threshold)
    return g, g2, plan, deg


def _levels(smoke: bool):
    return LEVELS[:2] if smoke else LEVELS


def counter_rows(seed: int = 0, smoke: bool = False,
                 built=None) -> List[Tuple[str, float, str]]:
    rows = []
    for (lvl, scale, t), (g, g2, plan, deg) in zip(
            _levels(smoke), built or _sweep(seed, smoke)):
        rep = mirror_report(g, g2, plan)
        skew = float(deg.max() / deg.mean())
        if skew >= 8.0:
            # the PR's acceptance gate, asserted where it must hold
            assert rep["alloc_ratio"] >= 4.0, (lvl, rep)
            assert rep["inter_split"] < rep["inter_unsplit"], (lvl, rep)
        rows.append((
            f"skew/{lvl}/alloc", float("nan"),
            f"skew={skew:.1f};ratio={rep['alloc_ratio']:.2f};"
            f"slots={rep['slots_unsplit']}->{rep['slots_split']};"
            f"inter={rep['inter_unsplit']}->{rep['inter_split']};"
            f"merge={rep['merge_payload']};groups={rep['n_groups']}"))
    return rows


def _sweep(seed: int, smoke: bool):
    return [_build_level(scale, t, seed)
            for _, scale, t in _levels(smoke)]


def kernel_rows(seed: int = 0, smoke: bool = False, prefix: str = "skew",
                built=None) -> List[Tuple[str, float, str]]:
    """Fused coreness fixpoint latency, unsplit vs split (+parity)."""
    rows = []
    reps = 3 if smoke else 10
    for (lvl, scale, t), (g, g2, plan, _) in zip(
            _levels(smoke), built or _sweep(seed, smoke)):
        c0 = coreness(g, backend="jnp")
        c1 = coreness(g2, backend="jnp", mirror=plan)
        m0 = dict(zip(np.asarray(g.orig_id)[np.asarray(g.node_mask)]
                      .tolist(),
                      np.asarray(c0)[np.asarray(g.node_mask)].tolist()))
        pm = np.asarray(plan.primary_mask)
        m1 = dict(zip(np.asarray(g2.orig_id)[pm].tolist(),
                      np.asarray(c1)[pm].tolist()))
        assert m0 == m1, f"split coreness diverged at level {lvl}"
        us0 = timeit_us(lambda: jax.block_until_ready(
            coreness(g, backend="jnp")), n=reps)
        us1 = timeit_us(lambda: jax.block_until_ready(
            coreness(g2, backend="jnp", mirror=plan)), n=reps)
        rows.append((f"{prefix}/{lvl}/coreness_unsplit", us0,
                     f"Cd={g.Cd}"))
        rows.append((f"{prefix}/{lvl}/coreness_split", us1,
                     f"Cd={g2.Cd};groups={plan.n_groups}"))
    return rows


def _hub_window(g2, plan, k: int = 8):
    """k inserts onto the heaviest primary (stays mirrored; primary ids)."""
    pm = np.asarray(plan.primary_mask)
    ldeg = np.asarray(plan.ldeg)
    hub = int(np.argmax(np.where(pm, ldeg, -1)))
    nbr = np.asarray(g2.nbr)
    prow = np.asarray(plan.primary_row)
    have = {int(prow[x]) for r in np.flatnonzero(prow == hub)
            for x in nbr[r] if x >= 0}
    out = []
    for v in np.flatnonzero(pm):
        v = int(v)
        if v != hub and v not in have:
            out.append((hub, v, +1))
        if len(out) == k:
            break
    return out


def stream_rows(seed: int = 0, smoke: bool = False, prefix: str = "skew",
                built=None) -> List[Tuple[str, float, str]]:
    """Host window-apply cost: plain splice vs mirrored slice routing."""
    rows = []
    reps = 3 if smoke else 10
    for (lvl, scale, t), (g, g2, plan, _) in zip(
            _levels(smoke), built or _sweep(seed, smoke)):
        window = _hub_window(g2, plan)
        # the same logical edits in each layout's own id space
        o2 = np.asarray(g2.orig_id)
        of_g = {int(o): i for i, o in enumerate(np.asarray(g.orig_id))
                if o >= 0}
        plain = [(of_g[int(o2[u])], of_g[int(o2[v])], op)
                 for u, v, op in window]
        us_plain = timeit_us(lambda: apply_updates_host(g, plain), n=reps)
        us_mirror = timeit_us(
            lambda: apply_mirrored_edits(g2, plan, window), n=reps)
        rows.append((f"{prefix}/{lvl}/window_plain", us_plain,
                     f"edits={len(window)}"))
        rows.append((f"{prefix}/{lvl}/window_mirror", us_mirror,
                     f"edits={len(window)};groups={plan.n_groups}"))
    return rows


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    built = _sweep(seed, smoke)
    rows = counter_rows(seed, smoke, built=built)
    rows += kernel_rows(seed, smoke, built=built)
    rows += stream_rows(seed, smoke, built=built)
    return rows
