"""Streaming runtime: incremental halo-plan maintenance + live rebalancing.

Three measurement surfaces for the stream-facing runtime work:

  * `stream/plan/*` — host cost of keeping the W2W halo plan in sync
    with one update window: the old full `build_halo_plan` rebuild
    (O(N*Cd) scan) vs `HaloPlan.apply_updates` (dirty workers only).
    The speedup is the window-rate headroom of the ingestion path.
  * `stream/run/*` — an `ell_spmd` stream pass with ONE threaded
    executor: wall time plus the plan-maintenance counters
    (`plan_updates` windows maintained incrementally, `plan_rebuilds`
    MUST be 0 in steady state — asserted here like the parity gates in
    bench_runtime).
  * `stream/rebalance/*` — the §4.2 threshold protocol live against a
    deliberately skewed block layout: balance + edge-cut + escalation
    trajectory without and with rebalancing, and the migration counts.
"""
from __future__ import annotations

import time
from typing import List, Tuple

import numpy as np

from repro.core import build_blocks, coreness
from repro.core.partition import node_bfs_partition
from repro.core.partition_dynamic import block_balance
from repro.core.updates import (
    apply_updates_host, sample_deletions, sample_insertions)
from repro.graphgen import barabasi_albert
from repro.runtime import build_halo_plan, make_worker_mesh, run_stream

from .common import row, timeit_us


def _mixed_updates(g, count: int, seed: int):
    per = max(1, count // 4)
    return (sample_insertions(g, per, "inter", seed=seed)
            + sample_insertions(g, per, "intra", seed=seed + 1)
            + sample_deletions(g, per, "inter", seed=seed + 2)
            + sample_deletions(g, per, "intra", seed=seed + 3))


def run(seed: int = 0, smoke: bool = False) -> List[Tuple[str, float, str]]:
    rows = []
    n = 400 if smoke else 4000
    reps = 3 if smoke else 10

    # ---- plan maintenance: full rebuild vs incremental -----------------
    edges = barabasi_albert(n, 4, seed=seed)
    nn = int(edges.max()) + 1
    assign = node_bfs_partition(edges, nn, 8, seed=seed)
    g = build_blocks(edges, nn, assign, P=8, deg_slack=48)
    wm = make_worker_mesh(g)
    plan = build_halo_plan(g, wm)
    window = _mixed_updates(g, 8, seed)
    g2 = apply_updates_host(g, window)
    t_full = timeit_us(lambda: build_halo_plan(
        g2, wm, H_min=plan.H, K_min=plan.K), n=reps)
    t_inc = timeit_us(lambda: plan.apply_updates(g2, window), n=reps)
    inc = plan.apply_updates(g2, window)
    fresh = build_halo_plan(g2, wm, H_min=plan.H, K_min=plan.K)
    assert (inc.nbr_local == fresh.nbr_local).all() and inc.H == fresh.H, \
        "incremental halo plan diverged from from-scratch build"
    rows.append(row("stream/plan/full_rebuild", t_full,
                    f"n={nn};P=8;W={wm.W};H={plan.H}"))
    rows.append(row("stream/plan/incremental", t_inc,
                    f"window=8;speedup={t_full / max(t_inc, 1e-9):.1f}x"))
    # the escalation path maintains per single edit: <= 2 dirty workers
    one = window[:1]
    g1 = apply_updates_host(g, one)
    t_one = timeit_us(lambda: plan.apply_updates(g1, one), n=reps)
    rows.append(row("stream/plan/incremental_1edit", t_one,
                    f"speedup={t_full / max(t_one, 1e-9):.1f}x"))

    # ---- executor reuse through a stream pass --------------------------
    sn = 160 if smoke else 800
    sedges = barabasi_albert(sn, 4, seed=seed + 7)
    snn = int(sedges.max()) + 1
    sg = build_blocks(sedges, snn, node_bfs_partition(sedges, snn, 4,
                                                      seed=seed),
                      P=4, deg_slack=48)
    score = coreness(sg, backend="jnp")
    ups = _mixed_updates(sg, 16, seed + 11)
    t0 = time.perf_counter()
    sres = run_stream(sg, score, list(ups), R=4, backend="ell_spmd")
    st = sres.stats
    dt = time.perf_counter() - t0
    assert st.plan_rebuilds == 0, \
        f"steady-state stream performed {st.plan_rebuilds} full rebuilds"
    rows.append(row("stream/run/ell_spmd", dt * 1e6 / max(1, st.updates),
                    f"updates={st.updates};plan_updates={st.plan_updates};"
                    f"plan_rebuilds={st.plan_rebuilds};"
                    f"escalated={st.escalated}"))

    # ---- live rebalancing: §4.2 threshold protocol ---------------------
    rn = 160 if smoke else 1200
    redges = barabasi_albert(rn, 4, seed=seed + 3)
    rnn = int(redges.max()) + 1
    skew = np.where(np.arange(rnn) < rnn // 2, 0, 1 + np.arange(rnn) % 3)
    Cn = int(-(-rnn // 2 // 8) * 8) + 16  # half the nodes + slack
    rg = build_blocks(redges, rnn, skew, P=4, Cn=Cn, deg_slack=48)
    rcore = coreness(rg, backend="jnp")
    rups = _mixed_updates(rg, 16, seed + 5)

    def _clone(gg):
        import jax
        import jax.numpy as jnp
        return jax.tree.map(
            lambda x: jnp.copy(x) if hasattr(x, "dtype") else x, gg)

    for label, thresh in (("off", None), ("on", 1.2)):
        rres = run_stream(_clone(rg), rcore, list(rups), R=4,
                          backend="jnp", rebalance_threshold=thresh,
                          rebalance_max_moves=8)
        gg, stt = rres.g, rres.stats
        rows.append(row(
            f"stream/rebalance/{label}", 0.0,
            f"balance={block_balance(gg):.2f};edge_cut={int(gg.edge_cut())};"
            f"escalated={stt.escalated};migrations={stt.migrations};"
            f"moved={stt.migrated_vertices}"))

    # ---- skew sweep: mirrored vs plain host window apply --------------
    from . import bench_skew
    rows += bench_skew.stream_rows(seed=seed, smoke=smoke,
                                   prefix="stream/skew")
    return rows


if __name__ == "__main__":
    from .common import print_rows
    print_rows(run())
